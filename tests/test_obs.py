"""Observability layer: registry/trace units + the engine acceptance run.

Unit layer (no engine): nearest-rank ``percentile``, the labeled
Counter/Gauge/Histogram registry with its Prometheus text exposition and
its consistency guards, the Chrome-trace recorder's event grammar, and the
``EngineMetrics`` façade — lazy throughput clock (``setup_s`` /
``compile_s`` split), phase timers, and the byte-compatibility golden list
of every pre-observability ``to_dict()`` key.

Engine layer (one module-scoped swap run, the exact workload
``tests/test_swap.py`` proves forces demote→promote round trips AND
promote stalls): with ``ObsConfig(trace=True, journal=True)``,

  * tokens are identical to the obs-off run — recording never perturbs
    the model path;
  * the trace is Perfetto-loadable JSON containing one COMPLETE request
    span (B/E ``request`` around ``queued`` B/E, a ``prefill`` X and >= 1
    ``decode`` X on the request's track) plus ``demote``/``promote``
    engine instants and a ``promote_stall`` request instant;
  * the journal replays CLEAN through ``replay_check``;
  * ``compile_s`` captured the first-trace compilation, phase timers
    populated, and the Prometheus snapshot exposes the families;
  * a default-constructed engine holds NO recording state at all;
  * the AOT roofline of the live decode fn reports nonzero FLOPs/bytes
    and ``achieved_vs_predicted`` scores a measured phase time against it.
"""
import dataclasses
import json
import time

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, ObsConfig, Request, SwapConfig,
)
from repro.serving.metrics import PHASES, EngineMetrics
from repro.serving.obs import (
    ENGINE_TID, EventJournal, MetricsRegistry, TraceRecorder, percentile,
    replay_check,
)

# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    xs = list(range(1, 101))          # 1..100
    assert percentile(xs, 0.50) == 50.0
    assert percentile(xs, 0.99) == 99.0
    assert percentile(xs, 1.0) == 100.0
    assert percentile(xs, 0.0) == 1.0
    assert percentile([], 0.5) == 0.0
    assert percentile([7.0], 0.99) == 7.0


def test_registry_counter_gauge_histogram():
    r = MetricsRegistry()
    c = r.counter("c_total", "a counter")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("g", "a gauge")
    g.set(4)
    g.set(2)
    assert g.value == 2.0
    h = r.histogram("h_seconds", "a histogram")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count == 4 and h.total == 10.0
    assert h.percentile(0.5) == 2.0


def test_registry_labels_memoized_and_guarded():
    r = MetricsRegistry()
    a = r.counter("tok_total", "by tier", tier=4)
    b = r.counter("tok_total", "by tier", tier=4)
    assert a is b                        # same label values -> same instrument
    c = r.counter("tok_total", "by tier", tier=8)
    assert c is not a
    # registering the same family name as a different kind is an error
    with pytest.raises(TypeError):
        r.gauge("tok_total")
    # ...as is changing the label keys
    with pytest.raises(ValueError):
        r.counter("tok_total", "by tier", shard=0)
    # get() never creates
    assert r.get("tok_total", tier=8) is c
    assert r.get("tok_total", tier=16) is None
    assert r.get("nope") is None


def test_registry_prometheus_exposition():
    r = MetricsRegistry()
    r.counter("lex_tokens_total", "tokens", tier=4).inc(7)
    r.gauge("lex_occupancy", "slots").set(3)
    h = r.histogram("lex_latency_seconds", "latency")
    for v in (0.25, 0.5, 0.75, 1.0):
        h.observe(v)
    text = r.to_prometheus()
    assert "# HELP lex_tokens_total tokens" in text
    assert "# TYPE lex_tokens_total counter" in text
    assert 'lex_tokens_total{tier="4"} 7' in text
    assert "# TYPE lex_occupancy gauge" in text
    assert "lex_occupancy 3" in text
    # histograms export as summaries: quantile rows + _sum/_count
    assert "# TYPE lex_latency_seconds summary" in text
    assert 'lex_latency_seconds{quantile="0.5"} 0.5' in text
    assert "lex_latency_seconds_sum 2.5" in text
    assert "lex_latency_seconds_count 4" in text
    # a flat snapshot carries the same values
    snap = r.snapshot()
    assert snap['lex_tokens_total{tier="4"}'] == 7.0
    assert snap["lex_latency_seconds_count"] == 4.0


# ---------------------------------------------------------------------------
# trace recorder
# ---------------------------------------------------------------------------


def test_trace_recorder_event_grammar():
    tr = TraceRecorder()
    tr.declare_thread(1, "req 0")
    tr.declare_thread(1, "req 0 again")       # once-only: ignored
    tr.begin("request", 1, rid=0)
    t0 = time.perf_counter()
    t1 = t0 + 0.001
    tr.complete("prefill", 1, t0, t1, bucket=16)
    tr.instant("demote", ENGINE_TID, page=3)
    tr.end("request", 1)

    doc = tr.to_chrome_trace()
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    # metadata: process name + engine thread + ONE req-0 thread row
    names = [e["args"]["name"] for e in evs if e["ph"] == "M"]
    assert "lexico-serving" in names and "engine" in names
    assert names.count("req 0") == 1 and "req 0 again" not in names
    by_ph = {ph: [e for e in evs if e["ph"] == ph]
             for ph in ("B", "E", "X", "i")}
    assert [e["name"] for e in by_ph["B"]] == ["request"]
    assert [e["name"] for e in by_ph["E"]] == ["request"]
    (x,) = by_ph["X"]
    assert x["name"] == "prefill" and x["args"]["bucket"] == 16
    assert x["dur"] == pytest.approx(1000.0, rel=0.01)   # 1ms in us
    (i,) = by_ph["i"]
    assert i["name"] == "demote" and i["tid"] == ENGINE_TID and i["s"] == "t"
    # every timestamped event is non-negative us from recorder birth
    assert all(e["ts"] >= 0 for e in evs if "ts" in e)
    assert json.loads(json.dumps(doc)) == doc            # JSON-serialisable
    assert len(tr) == len(evs)


# ---------------------------------------------------------------------------
# EngineMetrics: lazy clock, phases, byte-compatible to_dict
# ---------------------------------------------------------------------------

# every key the pre-observability EngineMetrics.to_dict() emitted, in
# order; regenerating this list from the new code would defeat the point
LEGACY_TO_DICT_KEYS = [
    "elapsed_s", "steps", "prefills", "requests_completed",
    "tokens_generated", "prompt_tokens_processed", "tokens_per_s",
    "decode_tokens_per_step", "slot_occupancy_mean", "slot_occupancy_peak",
    "kv_bytes_in_flight_mean", "kv_bytes_in_flight_peak",
    "kv_bytes_resident_mean", "kv_bytes_resident_peak", "pages_in_use_peak",
    "queue_latency_s_mean", "queue_latency_s_max",
    "prefill_tokens_compressed", "prefill_tokens_skipped", "prefix_hits",
    "prefix_misses", "shared_page_hit_rate", "pages_aliased", "pages_copied",
    "bytes_deduped", "shared_pages_peak", "pages_demoted", "pages_promoted",
    "promote_stall_steps", "host_bytes_resident_mean",
    "host_bytes_resident_peak",
]


def test_to_dict_preserves_every_legacy_key_in_order():
    md = EngineMetrics().to_dict()
    assert list(md)[:len(LEGACY_TO_DICT_KEYS)] == LEGACY_TO_DICT_KEYS
    # and the observability additions ride behind them
    for k in ("queue_latency_s_p50", "queue_latency_s_p99", "phase_times",
              "admission_rejections", "setup_s", "compile_s",
              "tokens_per_s_ex_compile"):
        assert k in md, k


def test_throughput_clock_starts_lazily():
    m = EngineMetrics()
    assert m.started_at is None
    assert m.elapsed_s == 0.0 and m.setup_s == 0.0
    time.sleep(0.05)                       # "engine construction / tracing"
    m.sample_step(occupancy=1, kv_bytes_in_flight=10)
    assert m.started_at is not None
    assert m.setup_s >= 0.05               # the gap landed in setup_s...
    assert m.elapsed_s < 0.05              # ...not in the throughput clock
    started = m.started_at
    m.record_admission(0.001)              # idempotent across both starters
    assert m.started_at == started


def test_compile_time_is_its_own_metric():
    m = EngineMetrics()
    m.start_clock()
    m.record_compile(1.5)
    m.record_compile(0.5)
    m.record_token(tier=8)
    md = m.to_dict()
    assert md["compile_s"] == 2.0
    # ex-compile throughput deducts it from the denominator
    assert md["tokens_per_s_ex_compile"] >= md["tokens_per_s"]


def test_phase_timers_summarize_with_percentiles():
    m = EngineMetrics()
    for i in range(100):
        m.record_phase("decode_dispatch", (i + 1) / 1000.0)
    m.record_phase("admit", 0.002)
    pt = m.to_dict()["phase_times"]
    dd = pt["decode_dispatch"]
    assert dd["count"] == 100
    assert dd["p50"] == pytest.approx(0.050)
    assert dd["p99"] == pytest.approx(0.099)
    assert dd["max"] == pytest.approx(0.100)
    assert "p999" not in dd                # needs >= 1000 samples
    for _ in range(1000):
        m.record_phase("host_sync", 0.001)
    assert "p999" in m.to_dict()["phase_times"]["host_sync"]
    assert set(pt) <= set(PHASES) | {"admit", "decode_dispatch"}
    # the same samples are visible through the registry family
    h = m.registry.get("lexico_step_phase_seconds", phase="admit")
    assert h is not None and h.count == 1


def test_queue_latency_percentiles_in_to_dict():
    m = EngineMetrics()
    for i in range(200):
        m.record_admission((i + 1) / 1000.0)
    md = m.to_dict()
    assert md["queue_latency_s_p50"] == pytest.approx(0.100)
    assert md["queue_latency_s_p99"] == pytest.approx(0.198)
    assert "queue_latency_s_p999" not in md
    for _ in range(800):
        m.record_admission(0.001)
    assert "queue_latency_s_p999" in m.to_dict()


def test_tier_labeled_families():
    m = EngineMetrics()
    m.start_clock()
    for tier in (2, 8, 8):
        m.record_token(tier)
    m.record_completion(tier=8)
    assert m.tokens_generated == 3
    assert m.registry.get("lexico_tier_tokens_generated_total",
                          tier=8).value == 2
    assert m.registry.get("lexico_tier_tokens_generated_total",
                          tier=2).value == 1
    text = m.to_prometheus()
    assert 'lexico_tier_tokens_generated_total{tier="8"} 2' in text
    assert 'lexico_tier_requests_completed_total{tier="8"} 1' in text


# ---------------------------------------------------------------------------
# engine acceptance: the swap workload, traced + journaled
# ---------------------------------------------------------------------------

CFG = configs.get_smoke("llama3.2-1b")
LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)


@pytest.fixture(scope="module")
def served():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    return params, bank


def _requests(rng):
    # the tests/test_swap.py workload: oversubscribes the 5-usable-page
    # pool, proven there to force demotions, promotions AND promote stalls
    spec = [(9, 3, 2), (30, 4, 8), (12, 2, 4), (26, 3, 6), (8, 2, 2)]
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, pl).astype(np.int32),
                    max_new_tokens=mn, tier=tier)
            for i, (pl, mn, tier) in enumerate(spec)]


def _run(params, bank, reqs, obs):
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, n_pages=6, swap=SwapConfig(), obs=obs))
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    done = eng.run()
    return {rid: done[rid].generated_tokens for rid in done}, eng


@pytest.fixture(scope="module")
def traced_run(served):
    params, bank = served
    reqs = _requests(np.random.default_rng(7))
    toks_off, eng_off = _run(params, bank, reqs, obs=None)
    toks_on, eng_on = _run(params, bank, reqs,
                           obs=ObsConfig(trace=True, journal=True))
    return toks_off, eng_off, toks_on, eng_on


def test_observed_run_emits_identical_tokens(traced_run):
    toks_off, _, toks_on, eng_on = traced_run
    assert toks_on == toks_off
    assert eng_on.metrics.pages_demoted > 0
    assert eng_on.metrics.pages_promoted > 0
    assert eng_on.metrics.promote_stall_steps > 0


def test_disabled_obs_holds_no_recording_state(traced_run):
    _, eng_off, _, _ = traced_run
    assert eng_off.tracer is None
    assert eng_off.journal is None
    assert eng_off.allocator.journal is None
    assert eng_off.swap.host.journal is None
    with pytest.raises(RuntimeError):
        eng_off.save_trace("/tmp/never.json")
    with pytest.raises(RuntimeError):
        eng_off.save_journal("/tmp/never.jsonl")


def test_trace_has_complete_request_spans(traced_run, tmp_path):
    """The acceptance artifact: a Perfetto-loadable trace whose request
    track carries the full lifecycle, with the swap instants present."""
    _, _, _, eng = traced_run
    path = tmp_path / "trace.json"
    eng.save_trace(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    assert all(isinstance(e.get("pid"), int) for e in evs)

    # rid 1 (prompt 30, the long request) rode through the swap pressure
    tid = 1 + 1
    on_track = [e for e in evs if e["tid"] == tid]
    names = [(e["name"], e["ph"]) for e in on_track]
    assert ("request", "B") in names and ("request", "E") in names
    assert ("queued", "B") in names and ("queued", "E") in names
    prefills = [e for e in on_track
                if e["name"] == "prefill" and e["ph"] == "X"]
    assert len(prefills) == 1 and prefills[0]["dur"] > 0
    assert prefills[0]["args"]["bucket"] == 16       # 30-token prompt,
    # largest power-of-two bucket <= prompt (the rest streams via decode)
    decodes = [e for e in on_track
               if e["name"] == "decode" and e["ph"] == "X"]
    assert len(decodes) >= 1
    # the request span opens before queued ends and closes after the last
    # decode — a well-nested lifecycle
    t_open = next(e["ts"] for e in on_track
                  if e["name"] == "request" and e["ph"] == "B")
    t_close = next(e["ts"] for e in on_track
                   if e["name"] == "request" and e["ph"] == "E")
    assert t_open <= min(e["ts"] for e in on_track if "ts" in e)
    assert t_close >= max(e["ts"] + e.get("dur", 0) for e in decodes)

    # swap lifecycle instants: demote/promote on the engine track, the
    # stall on the stalled request's own track
    engine_instants = {e["name"] for e in evs
                       if e["ph"] == "i" and e["tid"] == ENGINE_TID}
    assert "demote" in engine_instants and "promote" in engine_instants
    stalls = [e for e in evs if e["name"] == "promote_stall"]
    assert stalls and all(e["tid"] > ENGINE_TID for e in stalls)

    # every engine phase landed as a complete event on the engine track
    phase_names = {e["name"] for e in evs
                   if e["ph"] == "X" and e["tid"] == ENGINE_TID}
    assert phase_names >= set(PHASES)

    # and every request got a named track
    thread_rows = {e["args"]["name"] for e in evs
                   if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"engine", "req 0", "req 1", "req 2", "req 3", "req 4"} <= thread_rows


def test_journal_replays_clean_and_round_trips(traced_run, tmp_path):
    _, _, _, eng = traced_run
    violations = replay_check(eng.journal.events)
    assert violations == [], [str(v) for v in violations]
    evs = {e["ev"] for e in eng.journal.events}
    assert {"submit", "admit", "retire", "stall", "page_alloc",
            "page_decref", "page_demote", "page_promote", "host_put",
            "host_pop"} <= evs
    # save/load round trip preserves the events bit-for-bit
    path = tmp_path / "journal.jsonl"
    eng.save_journal(str(path))
    loaded = EventJournal.load(str(path))
    assert loaded == eng.journal.events
    assert replay_check(loaded) == []


def test_observed_metrics_capture_compile_and_phases(traced_run):
    _, _, _, eng = traced_run
    md = eng.metrics.to_dict()
    assert md["compile_s"] > 0.0           # first-trace compilation captured
    assert md["setup_s"] > 0.0
    assert md["tokens_per_s_ex_compile"] > md["tokens_per_s"]
    pt = md["phase_times"]
    # swap engine runs all six step phases; the admission-path "prefill"
    # timer appears too once any bucket prefills steady-state (post-compile)
    assert set(PHASES) <= set(pt) <= set(PHASES) | {"prefill"}
    for name in PHASES:
        assert pt[name]["count"] > 0 and pt[name]["p99"] >= pt[name]["p50"]
    assert md["queue_latency_s_p99"] >= md["queue_latency_s_p50"] >= 0.0
    text = eng.metrics.to_prometheus()
    for family in ("lexico_steps_total", "lexico_tokens_generated_total",
                   "lexico_pages_demoted_total",
                   'lexico_kv_bytes_resident{tier="host"}',
                   "lexico_step_phase_seconds"):
        assert family in text, family


def test_decode_roofline_from_live_engine(traced_run):
    from repro.roofline.analysis import achieved_vs_predicted
    from repro.serving.obs import engine_decode_roofline

    _, _, _, eng = traced_run
    report = engine_decode_roofline(eng)
    assert report.flops_per_device > 0
    assert report.bytes_per_device > 0
    assert report.bottleneck in ("compute", "memory", "collective")

    p50 = percentile(eng.metrics.phase_times["decode_dispatch"], 0.5)
    ap = achieved_vs_predicted(report, p50)
    assert ap["achieved_s"] == pytest.approx(p50)
    assert ap["predicted_s"] > 0
    assert ap["roofline_fraction"] == pytest.approx(
        ap["predicted_s"] / ap["achieved_s"])
    assert ap["achieved_flops_per_s"] > 0
