"""Tiered KV storage (host-memory swap): the differential + contract harness.

The swap tier rewires page residency under the decode loop (demote =
extract codes + null the holders' table entries + free the device id;
promote = re-allocate + inject + rebind), so the proof obligations are:

  * device round trip — ``extract_page``/``inject_page`` move a page's four
    sparse stores device→host→device bitwise;
  * engine differential — with a pool sized to force demotions, the
    swap-enabled engine emits tokens *identical* to an unconstrained
    no-swap run, with >= 1 page actually round-tripped device→host→device
    and both tiers balanced at drain;
  * oversubscription — concurrency the no-swap scheduler rejects
    (``FCFSScheduler.rejections``) is served by the tiered engine: all
    slots fill, stalled slots wait bit-identically, everything completes;
  * prefix-cache tiering — cached prefix pages are demoted in preference
    to being dropped, the trie entry survives pointing at a
    ``PageHandle``, and an admission-time hit *promotes* the page instead
    of recompressing the prefix;
  * two-tier accounting — ``kv_bytes_resident`` counts device pages only,
    ``host_bytes_resident`` counts the host tier, a demotion moves exactly
    one page's bytes between them (see also tests/test_memory_accounting).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.core import sparse_cache as sc
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, HostPageStore, HostTierFull,
    PageAllocator, PageHandle, PrefixIndex, Request, SwapConfig, SwapPolicy,
)

# ---------------------------------------------------------------------------
# device ops: extract_page / inject_page
# ---------------------------------------------------------------------------

L, KV, P, s = 2, 2, 4, 8


def _random_pool(rng, n_pages=5):
    shape = (L, n_pages, KV, P, s)
    return sc.PagedLexicoLayerCache(
        k_vals=jnp.asarray(rng.normal(size=shape), jnp.float8_e4m3fn),
        k_idx=jnp.asarray(rng.integers(0, 64, shape), jnp.int16),
        v_vals=jnp.asarray(rng.normal(size=shape), jnp.float8_e4m3fn),
        v_idx=jnp.asarray(rng.integers(0, 64, shape), jnp.int16),
        page_table=jnp.zeros((L, 1, 3), jnp.int32),
        k_buf=jnp.zeros((L, 1, KV, 2, 4), jnp.bfloat16),
        v_buf=jnp.zeros((L, 1, KV, 2, 4), jnp.bfloat16),
        t_c=jnp.zeros((L, 1), jnp.int32),
        buf_len=jnp.zeros((L, 1), jnp.int32),
        buf_start=jnp.zeros((L, 1), jnp.int32))


def test_extract_inject_round_trip_bitwise(rng):
    """A demote→promote round trip through numpy lands the identical bytes
    in a different pool page."""
    pool = _random_pool(rng)
    stores = sc.extract_page(pool, 3)
    host = tuple(np.asarray(x) for x in stores)      # the host-tier copy
    back = sc.inject_page(pool, 1, *(jnp.asarray(x) for x in host))
    for f, got in zip(("k_vals", "k_idx", "v_vals", "v_idx"),
                      (back.k_vals, back.k_idx, back.v_vals, back.v_idx)):
        src = np.asarray(getattr(pool, f)).astype(np.float32)
        dst = np.asarray(got).astype(np.float32)
        np.testing.assert_array_equal(dst[:, 1], src[:, 3], err_msg=f)
        # every other page untouched
        np.testing.assert_array_equal(dst[:, 0], src[:, 0], err_msg=f)
        np.testing.assert_array_equal(dst[:, 2:], src[:, 2:], err_msg=f)


def test_extract_page_single_layer_layout(rng):
    """The splices accept the unstacked (n_pages, KV, P, s) layout too."""
    stacked = _random_pool(rng)
    layer = jax.tree.map(lambda x: x[0], stacked,
                         is_leaf=lambda x: isinstance(x, jax.Array))
    got = sc.extract_page(layer, 2)[0]
    np.testing.assert_array_equal(
        np.asarray(got).astype(np.float32),
        np.asarray(stacked.k_vals).astype(np.float32)[0, 2:3])


# ---------------------------------------------------------------------------
# HostPageStore
# ---------------------------------------------------------------------------

def _stores(marker: float):
    return tuple(np.full((2, 3), np.float32(marker)) for _ in range(4))


def test_host_store_refcounts_and_bytes():
    h = HostPageStore(max_pages=2)
    a = h.put(_stores(1.0), refs=2)
    b = h.put(_stores(2.0), refs=1)
    assert h.n_pages == 2 and h.room() == 0
    assert h.handles() == [a, b]
    assert np.all(h.get(a)[0] == 1.0)                 # read-only peek
    assert h.bytes_resident == 8 * 2 * 3 * 4          # 8 arrays of 6 fp32
    with pytest.raises(HostTierFull):
        h.put(_stores(3.0), refs=1)
    h.incref(a)                         # a holder arriving while swapped
    assert not h.decref(a) and not h.decref(a)         # one holder left
    assert h.refcount(a) == 1
    stores, refs = h.pop(a)
    assert refs == 1 and np.all(stores[0] == 1.0)
    assert h.decref(b)
    with pytest.raises(KeyError, match="double free"):
        h.decref(b)
    assert h.check_balanced()
    with pytest.raises(ValueError, match=">= 1 holder"):
        h.put(_stores(4.0), refs=0)


def test_page_handles_are_not_device_pages():
    """Handles and device ids live in disjoint namespaces: a handle can
    never collide with (or be handed out as) an allocatable page id."""
    h = HostPageStore()
    handle = h.put(_stores(0.0), refs=1)
    assert isinstance(handle, PageHandle)
    a = PageAllocator(4, 2)
    assert all(isinstance(p, int) for p in a.alloc(3))
    assert handle not in a.allocated_pages()
    h.pop(handle)


# ---------------------------------------------------------------------------
# SwapPolicy
# ---------------------------------------------------------------------------

def test_cold_score_orders_by_recency_refs_and_hits():
    pol = SwapPolicy()
    # older = colder
    assert pol.cold_score(age=10, refs=1, hits=0) > \
        pol.cold_score(age=2, refs=1, hits=0)
    # fan-out and prefix hits keep a page warm at equal age
    base = pol.cold_score(age=10, refs=1, hits=0)
    assert pol.cold_score(age=10, refs=3, hits=0) < base
    assert pol.cold_score(age=10, refs=1, hits=2) < base


def test_subtree_evict_key_prefers_unpopular_large_subtrees():
    pol = SwapPolicy()
    cold_big = pol.subtree_evict_key(hits=0, pages=4, last_used=5)
    cold_small = pol.subtree_evict_key(hits=0, pages=1, last_used=5)
    hot = pol.subtree_evict_key(hits=6, pages=2, last_used=5)
    assert cold_big < cold_small < hot
    # equal hit density: LRU breaks the tie
    older = pol.subtree_evict_key(hits=0, pages=2, last_used=1)
    newer = pol.subtree_evict_key(hits=0, pages=2, last_used=9)
    assert older < newer


# ---------------------------------------------------------------------------
# PrefixIndex across tiers
# ---------------------------------------------------------------------------

def test_prefix_index_swap_out_keeps_entry_shareable():
    a = PageAllocator(16, 4)
    h = HostPageStore()
    idx = PrefixIndex(4)
    pages = a.alloc(2)
    toks = list(range(8))
    idx.register(toks, tier=8, pages=pages, n_codes=8, allocator=a)
    a.free(pages)                       # donor retired: index-only pins
    assert idx.evictable_pages(a) == 2

    # demote page 0 of the cached prefix: entry survives, re-keyed
    handle = h.put(_stores(0.0), refs=a.demote(pages[0]))
    assert idx.swap_out(pages[0], handle)
    assert idx.evictable_pages(a) == 1          # device pages only
    assert idx.n_cached_pages() == 2            # the entry survived the move
    assert not idx.swap_out(pages[0], handle)   # already re-keyed
    plan = idx.lookup(toks, tier=8, n_codes=8)
    assert plan.hit and plan.aliased == [handle, pages[1]]

    # promote back (possibly into a different device id) and hit again
    stores, refs = h.pop(handle)
    back = a.promote(refs)
    assert idx.swap_in(handle, back)
    plan = idx.lookup(toks, tier=8, n_codes=8)
    assert plan.aliased == [back, pages[1]]
    idx.clear(a, host=h)
    assert a.check_balanced() and h.check_balanced()


def test_prefix_index_clear_drops_swapped_pins():
    a = PageAllocator(8, 4)
    h = HostPageStore()
    idx = PrefixIndex(4)
    (page,) = a.alloc(1)
    idx.register([1, 2, 3, 4], tier=8, pages=[page], n_codes=4, allocator=a)
    a.free([page])
    handle = h.put(_stores(0.0), refs=a.demote(page))
    idx.swap_out(page, handle)
    with pytest.raises(ValueError, match="host store"):
        idx.clear(a)                    # swapped pin needs the host store
    # the failed clear already unpinned nothing host-side; retry with it
    idx.register([9, 9, 9, 9], tier=8, pages=a.alloc(1), n_codes=4,
                 allocator=a)
    idx.clear(a, host=h)
    assert h.check_balanced()


# ---------------------------------------------------------------------------
# engine differential (the acceptance gate)
# ---------------------------------------------------------------------------

CFG = configs.get_smoke("llama3.2-1b")
LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)


@pytest.fixture(scope="module")
def served():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    return params, bank


def _requests(rng):
    # short/long mix whose concurrent working set (~7 pages) oversubscribes
    # the 5-usable-page pool below, while each request alone fits (<= 4)
    spec = [(9, 3, 2), (30, 4, 8), (12, 2, 4), (26, 3, 6), (8, 2, 2)]
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, pl).astype(np.int32),
                    max_new_tokens=mn, tier=tier)
            for i, (pl, mn, tier) in enumerate(spec)]


def _run(params, bank, reqs, **cfg_kw):
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, **cfg_kw))
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    done = eng.run()
    return {rid: done[rid].generated_tokens for rid in done}, eng


def test_engine_swap_matches_unconstrained_bitwise(served):
    """The acceptance gate: a pool sized to force demotions + swap emits
    tokens identical to an unconstrained no-swap run; >= 1 page actually
    round-tripped device→host→device; concurrency the no-swap scheduler
    rejected is served (slots fill, stalls absorb the pressure); both tiers
    balance at drain."""
    params, bank = served
    reqs = _requests(np.random.default_rng(7))

    oracle, _ = _run(params, bank, reqs)                     # full pool
    noswap, noswap_eng = _run(params, bank, reqs, n_pages=6)
    swapped, eng = _run(params, bank, reqs, n_pages=6, swap=SwapConfig())

    assert sorted(swapped) == sorted(oracle)
    for rid in oracle:
        assert swapped[rid] == oracle[rid], rid
    assert noswap == oracle                                  # sanity

    md = eng.metrics.to_dict()
    # >= 1 page genuinely went device→host→device
    assert md["pages_demoted"] > 0
    assert md["pages_promoted"] > 0
    assert eng.allocator.pages_demoted == md["pages_demoted"]
    assert md["host_bytes_resident_peak"] > 0
    # the device pool never overflowed, and residency waits were taken as
    # stalls rather than wrong reads
    assert md["pages_in_use_peak"] <= 5
    assert md["promote_stall_steps"] > 0

    # oversubscription the no-swap run rejected is served concurrently:
    # the plain page budget head-of-line blocked (rejections), the tiered
    # engine filled every slot
    assert noswap_eng.scheduler.rejections > 0
    assert (md["slot_occupancy_peak"]
            > noswap_eng.metrics.to_dict()["slot_occupancy_peak"])

    # one compiled trace per tier-transfer op, like every other splice
    cc = eng.compile_counts
    assert cc["extract_page"] == 1 and cc["inject_page"] == 1, cc
    assert cc["decode"] == 1, cc

    # two-tier balance at drain
    assert eng.allocator.check_balanced()
    assert eng.swap.host.check_balanced()
    assert eng.host_bytes_resident() == 0


def test_engine_swap_accounting_never_double_counts(served):
    """Mid-run: device-resident bytes + host-resident bytes account every
    held page exactly once, and demotions move a page's bytes wholesale."""
    params, bank = served
    reqs = _requests(np.random.default_rng(7))
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, n_pages=6, swap=SwapConfig()))
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    page_bytes = CFG.num_layers * sc.page_store_bytes(
        CFG.cache_kv_heads, 8, LEX.s)
    saw_swapped = False
    while eng.step():
        device_pages = {p for i in eng.pool.active_slots()
                        for p in eng.pool.slots[i].device_pages}
        swapped = [p for i in eng.pool.active_slots()
                   for p in eng.pool.slots[i].swapped_pages]
        # host tier bytes == swapped page count * per-page bytes, and the
        # device view counts exactly the device-resident pages
        assert eng.host_bytes_resident() == eng.swap.host.n_pages * page_bytes
        assert len(set(swapped)) == eng.swap.host.n_pages
        ring = CFG.num_layers * sc.slot_resident_bytes(
            0, kv_heads=CFG.cache_kv_heads, page_size=8, s=LEX.s,
            n_b=LEX.n_b, m=CFG.cached_vector_dim)
        assert eng.kv_bytes_resident() == (
            len(device_pages) * page_bytes
            + len(eng.pool.active_slots()) * ring)
        saw_swapped = saw_swapped or bool(swapped)
    assert saw_swapped, "the trace never actually swapped"
    assert eng.allocator.check_balanced() and eng.swap.host.check_balanced()


def test_engine_swap_requires_paged_layout(served):
    params, bank = served
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(
            params, CFG, LEX, bank,
            EngineConfig(n_slots=2, t_max=64, min_bucket=8,
                         layout="contiguous", swap=SwapConfig()))


# ---------------------------------------------------------------------------
# prefix cache across tiers: demote instead of drop, promote instead of
# recompress
# ---------------------------------------------------------------------------

def _family_requests(rng, n_tail=3):
    prefix = rng.integers(0, CFG.vocab_size, 32).astype(np.int32)
    sharers = [Request(rid=i, prompt=np.concatenate(
                   [prefix, rng.integers(0, CFG.vocab_size, k).astype(np.int32)]),
                   max_new_tokens=3, tier=8)
               for i, k in enumerate((2, 6))]
    fillers = [Request(rid=2 + i,
                       prompt=rng.integers(0, CFG.vocab_size, 24).astype(np.int32),
                       max_new_tokens=3, tier=8) for i in range(2)]
    late = Request(rid=4, prompt=np.concatenate(
        [prefix, rng.integers(0, CFG.vocab_size, n_tail).astype(np.int32)]),
        max_new_tokens=3, tier=8)
    return sharers + fillers + [late]


def test_prefix_hits_on_swapped_pages_promote_not_recompress(served):
    """Filler pressure demotes the retired sharers' cached prefix pages
    (instead of dropping them); the late sharer's admission hits the
    swapped entries and PROMOTES them — prefill OMP is still skipped and
    tokens still match the unshared oracle bitwise."""
    params, bank = served
    reqs = _family_requests(np.random.default_rng(21))
    oracle, _ = _run(params, bank, reqs, share_prefixes=False)
    shared, eng = _run(params, bank, reqs, share_prefixes=True, n_pages=9,
                       swap=SwapConfig())
    assert shared == oracle

    md = eng.metrics.to_dict()
    assert md["pages_demoted"] > 0, "no pressure reached the prefix cache"
    assert md["pages_promoted"] > 0, "no swapped page was ever re-used"
    assert md["prefix_hits"] >= 2            # the second sharer + the late one
    assert md["prefill_tokens_skipped"] > 0
    # demote-not-drop: cache entries survived the pressure (possibly as
    # handles) rather than being destroyed
    assert eng.prefix_index.n_cached_pages() > 0

    eng.prefix_index.clear(eng.allocator, host=eng.swap.host)
    assert eng.allocator.check_balanced()
    assert eng.swap.host.check_balanced()


def test_watermark_demotes_index_only_pages_proactively(served):
    """The proactive trim: with a high watermark, retired sharers' cached
    pages are demoted to the host tier without any allocation failing —
    free-list headroom is restored while the trie entries survive."""
    params, bank = served
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, CFG.vocab_size, 32).astype(np.int32)
    req = Request(rid=0, prompt=prefix.copy(), max_new_tokens=3, tier=8)
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=1, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, n_pages=8, share_prefixes=True,
                     swap=SwapConfig(watermark_pages=6)))
    eng.submit(req)
    eng.run()
    # prefill pinned 4 pages (28 codes); the watermark demoted enough of
    # them to restore >= 6 free device pages, keeping the entries cached
    assert eng.allocator.n_free >= 6
    assert eng.swap.host.n_pages >= 3
    assert eng.metrics.pages_demoted >= 3
    assert eng.prefix_index.n_cached_pages() >= 3
    # ...and a rerun of the same prefix still HITS (promoting, not
    # recompressing): strictly fewer fresh OMP positions
    before = eng.metrics.prefill_tokens_skipped
    eng.submit(Request(rid=1, prompt=prefix.copy(), max_new_tokens=3, tier=8))
    eng.run()
    assert eng.metrics.prefill_tokens_skipped > before
    assert eng.metrics.pages_promoted > 0
    eng.prefix_index.clear(eng.allocator, host=eng.swap.host)
    assert eng.allocator.check_balanced()
    assert eng.swap.host.check_balanced()
