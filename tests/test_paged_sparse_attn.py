"""Fused paged sparse-attention: the kernel-parity contract.

Four layers of pinning, per docs/kernels.md:

  * differential sweep — the Pallas kernel (interpret mode) vs the
    gather-then-mask oracle ``ref.paged_attention_ref`` across ragged rows,
    trash-page slots, single-page and pool-spanning tables, mixed sparsity
    tiers, and tile sizes that do / don't divide ``page_size``;
  * property harness (hypothesis, optional) — idle rows return the init
    carry bitwise, rows are independent, and physically relocating pool
    pages (table remap) changes nothing;
  * dispatch table — ``resolve_dispatch`` pinned for every
    (backend, force_kernel, interpret) cell, and all four ops proven to
    route through it (``force_kernel=True`` off-TPU must run the kernel in
    interpret mode, never silently fall back to the oracle);
  * engine acceptance — ``fused_attention`` on vs off produces identical
    greedy tokens on a prefix-shared + swap-tiered workload with the decode
    compile count still exactly 1.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.core import sparse_cache as SC
from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.paged_sparse_attn import NEG_INF, paged_sparse_attention
from repro.models import model as M
from repro.roofline.kernel_model import (
    PagedAttnShape, compare_paged_attention, fused_path_bytes,
    gather_path_bytes,
)
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, Request, SwapConfig,
)
from tests.conftest import given, settings, st

# Online softmax (kernel) vs single-pass softmax (oracle) reorder fp32
# accumulation; both read identical storage, so the gap is rounding only.
TOL = dict(atol=2e-5, rtol=1e-5)


def make_pool(rng, *, n_pages, KV, P, s, N, vdtype=jnp.float32,
              idtype=jnp.int32):
    """Random pool; the trash page 0 carries large finite garbage so any
    unmasked read blows past TOL instead of hiding in the noise."""
    def vals():
        v = rng.normal(size=(n_pages, KV, P, s))
        v[0] = 100.0
        return jnp.asarray(v, jnp.float32).astype(vdtype)

    def idxs():
        return jnp.asarray(rng.integers(0, N, (n_pages, KV, P, s)), idtype)

    return vals(), idxs(), vals(), idxs()


def run_both(rng, *, table, t_c, min_pos=None, n_pages=7, KV=2, G=2, P=8,
             s=4, N=64, block_t=None, vdtype=jnp.float32, idtype=jnp.int32,
             scale=0.25):
    table = jnp.asarray(table, jnp.int32)
    B = table.shape[0]
    t_c = jnp.asarray(t_c, jnp.int32)
    mp = (jnp.full((B,), -1, jnp.int32) if min_pos is None
          else jnp.asarray(min_pos, jnp.int32))
    kv, ki, vv, vi = make_pool(rng, n_pages=n_pages, KV=KV, P=P, s=s, N=N,
                               vdtype=vdtype, idtype=idtype)
    qd = jnp.asarray(rng.normal(size=(B, KV, G, N)), jnp.float32)
    got = paged_sparse_attention(qd, kv, ki, vv, vi, table, t_c, mp,
                                 N=N, scale=scale, block_t=block_t,
                                 interpret=True)
    want = ref.paged_attention_ref(qd, kv, ki, vv, vi, table, t_c, mp,
                                   N=N, scale=scale)
    return got, want


def assert_carry_close(got, want, **tol):
    for g, w, name in zip(got, want, ("m", "l", "c")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   err_msg=name, **(tol or TOL))


# ---------------------------------------------------------------------------
# differential sweep vs the gather-then-mask oracle
# ---------------------------------------------------------------------------

RAGGED = dict(
    table=[[1, 2, 3], [4, 0, 0], [0, 0, 0], [6, 5, 1]],
    # full row / partial page / idle / pool-spanning non-monotone pages
    t_c=[24, 5, 0, 17])


@pytest.mark.parametrize("block_t", [None, 8, 5, 3, 1])
def test_parity_ragged_rows(rng, block_t):
    """Ragged t_c (full, partial-page, idle, spanning) at every tile size —
    including block_t 5 and 3, which do NOT divide page_size=8 (pad-masked
    tail tiles)."""
    got, want = run_both(rng, **RAGGED, block_t=block_t)
    assert_carry_close(got, want)


def test_parity_single_page_tables(rng):
    """max_pages == 1: the degenerate table the grid must still walk."""
    got, want = run_both(rng, table=[[2], [0]], t_c=[6, 0])
    assert_carry_close(got, want)


def test_parity_trash_page_rows(rng):
    """Null tables clamp onto the trash page; its garbage must be masked
    out entirely (t_c = 0) or beyond t_c (short row on real page 1)."""
    got, want = run_both(rng, table=[[0, 0], [1, 0]], t_c=[0, 9])
    assert_carry_close(got, want)
    # the idle row's carry is the exact init, not merely close
    m, l, c = got
    np.testing.assert_array_equal(np.asarray(m)[0], np.float32(NEG_INF))
    np.testing.assert_array_equal(np.asarray(l)[0], 0.0)
    np.testing.assert_array_equal(np.asarray(c)[0], 0.0)


def test_parity_window_min_pos(rng):
    """Sliding-window floors: per-row min_pos masks old positions."""
    got, want = run_both(rng, **RAGGED, min_pos=[10, 2, -1, 17])
    assert_carry_close(got, want)


@pytest.mark.parametrize("vdtype,idtype", [
    (jnp.float32, jnp.int32),
    (jnp.float8_e4m3fn, jnp.int16),   # the serving fp8 codec layout
    (jnp.bfloat16, jnp.int16),
])
def test_parity_storage_dtypes(rng, vdtype, idtype):
    got, want = run_both(rng, **RAGGED, vdtype=vdtype, idtype=idtype)
    assert_carry_close(got, want)


@pytest.mark.parametrize("s,N,G", [(2, 32, 1), (8, 128, 4)])
def test_parity_shape_corners(rng, s, N, G):
    """Sparsity tiers and GQA widths around the defaults."""
    got, want = run_both(rng, table=[[1, 2], [3, 0]], t_c=[13, 4],
                         s=s, N=N, G=G, block_t=3)
    assert_carry_close(got, want)


def test_fused_attend_matches_gather_attend(rng):
    """End-to-end paged_attend: fused (oracle and forced kernel) equals the
    gather path on every live row, and equals the flash-chunked convention
    bitwise on idle rows (chunk=None gives idle rows a different — equally
    unconsumed — garbage, so they are excluded from the gather comparison)."""
    B, KV, G, m, N, s, P, n_pages = 3, 2, 2, 16, 64, 4, 8, 7
    kv, ki, vv, vi = make_pool(rng, n_pages=n_pages, KV=KV, P=P, s=s, N=N)
    cache = SC.PagedLexicoLayerCache(
        k_vals=kv, k_idx=ki, v_vals=vv, v_idx=vi,
        page_table=jnp.asarray([[1, 2, 3], [4, 0, 0], [0, 0, 0]], jnp.int32),
        k_buf=jnp.asarray(rng.normal(size=(B, KV, 4, m)), jnp.float32),
        v_buf=jnp.asarray(rng.normal(size=(B, KV, 4, m)), jnp.float32),
        t_c=jnp.asarray([20, 5, 0], jnp.int32),
        buf_len=jnp.asarray([4, 2, 0], jnp.int32),
        buf_start=jnp.zeros((B,), jnp.int32))
    q = jnp.asarray(rng.normal(size=(B, KV, G, m)), jnp.float32)
    D_k = jnp.asarray(rng.normal(size=(m, N)), jnp.float32)
    D_v = jnp.asarray(rng.normal(size=(m, N)), jnp.float32)
    for window in (None, jnp.int32(10)):
        o_ref = np.asarray(SC.paged_attend(cache, q, D_k, D_v, N=N,
                                           window=window))
        o_chunk = np.asarray(SC.paged_attend(cache, q, D_k, D_v, N=N,
                                             chunk=P, window=window))
        for fk in (False, True):
            o_f = np.asarray(SC.paged_attend(cache, q, D_k, D_v, N=N,
                                             window=window, fused=True,
                                             fused_force_kernel=fk))
            np.testing.assert_allclose(o_f[:2], o_ref[:2], atol=1e-5,
                                       rtol=1e-5)
            np.testing.assert_allclose(o_f, o_chunk, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# property harness (hypothesis; skips cleanly when not installed)
# ---------------------------------------------------------------------------

def _tiny_case(seed, B, MP):
    """Small random pool + tables, sized so interpret-mode runs stay fast."""
    rng = np.random.default_rng(seed)
    n_pages, KV, G, P, s, N = 5, 1, 1, 4, 2, 32
    kv, ki, vv, vi = make_pool(rng, n_pages=n_pages, KV=KV, P=P, s=s, N=N)
    table = jnp.asarray(rng.integers(0, n_pages, (B, MP)), jnp.int32)
    t_c = jnp.asarray(rng.integers(0, MP * P + 1, (B,)), jnp.int32)
    qd = jnp.asarray(rng.normal(size=(B, KV, G, N)), jnp.float32)
    mp = jnp.full((B,), -1, jnp.int32)
    return rng, (qd, kv, ki, vv, vi, table, t_c, mp), dict(N=N, scale=0.5)


def _run(arrs, kw, **over):
    return paged_sparse_attention(*arrs, **kw, interpret=True, **over)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), B=st.sampled_from([1, 3]),
       MP=st.sampled_from([1, 3]))
def test_property_idle_rows_bit_identical(seed, B, MP):
    """Any row with t_c == 0 returns exactly the init carry, bitwise,
    whatever the rest of the batch holds."""
    rng, arrs, kw = _tiny_case(seed, B, MP)
    qd, kv, ki, vv, vi, table, t_c, mp = arrs
    t_c = t_c.at[0].set(0)
    m, l, c = _run((qd, kv, ki, vv, vi, table, t_c, mp), kw)
    np.testing.assert_array_equal(np.asarray(m)[0], np.float32(NEG_INF))
    np.testing.assert_array_equal(np.asarray(l)[0], 0.0)
    np.testing.assert_array_equal(np.asarray(c)[0], 0.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), MP=st.sampled_from([1, 3]))
def test_property_rows_independent(seed, MP):
    """Rewriting one row's table/t_c leaves every other row's carry
    bit-identical (the grid never mixes rows)."""
    rng, arrs, kw = _tiny_case(seed, 3, MP)
    qd, kv, ki, vv, vi, table, t_c, mp = arrs
    base = _run(arrs, kw)
    table2 = table.at[1].set(jnp.asarray(rng.integers(0, 5, MP), jnp.int32))
    t_c2 = t_c.at[1].set(int(rng.integers(0, MP * 4 + 1)))
    pert = _run((qd, kv, ki, vv, vi, table2, t_c2, mp), kw)
    for a, b in zip(base, pert):
        np.testing.assert_array_equal(np.asarray(a)[[0, 2]],
                                      np.asarray(b)[[0, 2]])


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), B=st.sampled_from([1, 3]),
       MP=st.sampled_from([1, 3]))
def test_property_page_permutation_invariant(seed, B, MP):
    """Physically relocating pool pages (and remapping every table entry)
    is invisible: logical position order fixes the accumulation order, so
    the carry is bit-identical. Page 0 stays the null page."""
    rng, arrs, kw = _tiny_case(seed, B, MP)
    qd, kv, ki, vv, vi, table, t_c, mp = arrs
    base = _run(arrs, kw)
    perm = np.concatenate([[0], 1 + rng.permutation(4)])   # fix trash page
    inv = np.argsort(perm)

    def relocate(pool):
        return jnp.asarray(np.asarray(pool)[inv])

    table2 = jnp.asarray(perm[np.asarray(table)], jnp.int32)
    moved = _run((qd, relocate(kv), relocate(ki), relocate(vv),
                  relocate(vi), table2, t_c, mp), kw)
    for a, b in zip(base, moved):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# dispatch contract: resolve_dispatch + all four ops route through it
# ---------------------------------------------------------------------------

def test_dispatch_table(monkeypatch):
    """The (backend, force_kernel, interpret) -> (use_kernel, interpret)
    table, pinned cell by cell. The load-bearing row: force_kernel=True
    with interpret=None off-TPU runs the kernel in interpret mode."""
    cases = {
        # on_tpu: {(force_kernel, interpret): (use_kernel, interpret_mode)}
        False: {
            (False, None): (False, True),
            (True, None): (True, True),      # never the silent oracle
            (False, True): (True, True),     # interpret=True is an opt-in
            (True, True): (True, True),
            (False, False): (False, False),
            (True, False): (True, False),
        },
        True: {
            (False, None): (True, False),    # native kernel by default
            (True, None): (True, False),
            (False, True): (True, True),
            (True, True): (True, True),
            (False, False): (True, False),
            (True, False): (True, False),
        },
    }
    for on_tpu, table in cases.items():
        monkeypatch.setattr(ops, "_on_tpu", lambda v=on_tpu: v)
        for (fk, interp), want in table.items():
            assert ops.resolve_dispatch(fk, interp) == want, (
                on_tpu, fk, interp)


def test_all_ops_share_dispatch(rng, monkeypatch):
    """Each of the four ops calls its kernel exactly when resolve_dispatch
    says so — sentinel-stubbed kernels and oracles, off-TPU."""
    monkeypatch.setattr(ops, "_on_tpu", lambda: False)
    calls = []

    def stub(name):
        def f(*a, **k):
            calls.append(name)
            return "out"
        return f

    monkeypatch.setattr(ops, "sparse_scores", stub("kernel"))
    monkeypatch.setattr(ops, "sparse_values", stub("kernel"))
    monkeypatch.setattr(ops, "omp_corr_argmax", stub("kernel"))
    monkeypatch.setattr(ops, "omp_gram_argmax", stub("kernel"))
    monkeypatch.setattr(ops, "paged_sparse_attention", stub("kernel"))
    monkeypatch.setattr(ops.ref, "sparse_scores_ref", stub("oracle"))
    monkeypatch.setattr(ops.ref, "sparse_values_ref", stub("oracle"))
    monkeypatch.setattr(ops.ref, "omp_corr_ref", stub("oracle"))
    monkeypatch.setattr(ops.ref, "omp_gram_corr_ref", stub("oracle"))
    monkeypatch.setattr(ops.ref, "paged_attention_ref", stub("oracle"))

    every_op = [
        lambda **kw: ops.scores_op(None, None, None, **kw),
        lambda **kw: ops.values_op(None, None, None, N=8, **kw),
        lambda **kw: ops.omp_select_op(None, None, None, **kw),
        lambda **kw: ops.omp_gram_select_op(None, None, None, None, None, **kw),
        lambda **kw: ops.paged_attention_op(
            None, None, None, None, None, None, None, None,
            N=8, scale=1.0, **kw),
    ]
    for op in every_op:
        for kw, want in [
            (dict(), "oracle"),
            (dict(force_kernel=True), "kernel"),
            (dict(interpret=True), "kernel"),
        ]:
            calls.clear()
            op(**kw)
            assert calls == [want], (op, kw, calls)


# ---------------------------------------------------------------------------
# analytic kernel model: fused must predict strictly fewer HBM bytes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    PagedAttnShape(batch=1, kv_heads=1, q_per_kv=1, head_dim=16,
                   n_dict=64, s=2, pages_per_row=1, page_size=4),
    PagedAttnShape(batch=4, kv_heads=4, q_per_kv=2, head_dim=16,
                   n_dict=192, s=16, pages_per_row=12, page_size=8),
    PagedAttnShape(batch=8, kv_heads=8, q_per_kv=4, head_dim=64,
                   n_dict=4096, s=16, pages_per_row=256, page_size=16),
])
def test_kernel_model_fused_strictly_fewer_bytes(shape):
    g, f = gather_path_bytes(shape), fused_path_bytes(shape)
    assert f["total_bytes"] < g["total_bytes"], shape
    # the fused win is the dropped copy/reread + logits traffic
    assert g["total_bytes"] - f["total_bytes"] >= (
        g["gather_write"] + g["gather_reread"])
    cmp = compare_paged_attention(shape)
    assert cmp["bytes_ratio"] < 1.0
    assert cmp["fused"]["t_roofline_s"] <= cmp["gather"]["t_roofline_s"]
    # FLOPs are shared by construction: same math, different traffic
    assert cmp["flops"] == shape.flops


# ---------------------------------------------------------------------------
# engine acceptance: fused on/off token identity, compile counts unchanged
# ---------------------------------------------------------------------------

CFG = configs.get_smoke("llama3.2-1b")
LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)


@pytest.fixture(scope="module")
def served():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    return params, bank


def _shared_prefix_requests(rng, n=5):
    """Prefix-shareable + long enough to spill pages into the swap tier:
    one 16-token system prompt (page-aligned at page_size 8), per-request
    tails, one tier (sharing requires equal OMP caps)."""
    system = rng.integers(0, CFG.vocab_size, 16).astype(np.int32)
    reqs = []
    for rid in range(n):
        tail = rng.integers(0, CFG.vocab_size,
                            int(rng.integers(2, 14))).astype(np.int32)
        reqs.append(Request(rid=rid, prompt=np.concatenate([system, tail]),
                            max_new_tokens=int(rng.integers(3, 6)), tier=8))
    return reqs


def test_engine_fused_token_identity(served):
    """The acceptance gate: fused_attention on (oracle AND forced kernel)
    reproduces the gather engine's greedy tokens exactly on a workload that
    exercises prefix sharing and the host swap tier, and the decode step
    still compiles exactly once."""
    params, bank = served
    base = EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                        page_size=8, n_pages=18, share_prefixes=True,
                        swap=SwapConfig())
    tokens, engines = {}, {}
    for mode, over in (("off", {}),
                       ("fused", dict(fused_attention=True)),
                       ("fused_kernel", dict(fused_attention=True,
                                             fused_force_kernel=True))):
        eng = ContinuousBatchingEngine(params, CFG, LEX, bank,
                                       dataclasses.replace(base, **over))
        for r in _shared_prefix_requests(np.random.default_rng(11)):
            eng.submit(r)
        done = eng.run()
        tokens[mode] = {rid: done[rid].generated_tokens for rid in done}
        engines[mode] = eng
    assert tokens["fused"] == tokens["off"]
    assert tokens["fused_kernel"] == tokens["off"]
    for mode, eng in engines.items():
        cc = eng.compile_counts
        assert cc["decode"] == 1, (mode, cc)
        # the workload actually exercised what it claims to
        assert eng.metrics.to_dict()["requests_completed"] == 5, mode


def test_engine_fused_requires_paged_layout(served):
    params, bank = served
    with pytest.raises(ValueError, match="fused_attention requires"):
        ContinuousBatchingEngine(
            params, CFG, LEX, bank,
            EngineConfig(n_slots=2, t_max=64, min_bucket=8,
                         layout="contiguous", fused_attention=True))
