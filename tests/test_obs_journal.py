"""Journal replay checker vs the real allocator: clean traces stay clean,
injected corruption is caught.

The emitting side is the production one — a ``PageAllocator`` and a
``HostPageStore`` with an ``EventJournal`` attached journal every alloc /
incref / decref / demote→put / pop→promote they actually perform.  A
randomized driver (the same op mix as ``tests/test_slot_lifecycle_fuzz``,
shrunk) produces journals that MUST replay clean; the negative tests then
tamper with those real journals — deleting, duplicating or rewriting
single events — and assert :func:`replay_check` pins each corruption:

  * duplicated ``page_decref``  -> ``double-free``
  * deleted   ``page_decref``  -> ``device-leak`` at end of trace
  * deleted   ``host_pop``     -> ``host-leak`` + ``tier-transfer-mismatch``
                                  + ``promote-onto-live-page``-free replay
  * rewritten transfer refcount -> ``refcount-divergence`` +
                                  ``tier-transfer-mismatch``
  * ``page_alloc`` of page 0    -> ``null-page-alloc``
  * use-after-free incref       -> ``incref-after-free``
"""
import copy

import numpy as np
import pytest

from repro.serving import HostPageStore, PageAllocator
from repro.serving.obs import EventJournal, replay_check


def _journaled_pair(n_pages=8):
    alloc = PageAllocator(n_pages, page_size=4)
    host = HostPageStore()
    journal = EventJournal()
    alloc.journal = journal
    host.journal = journal
    return alloc, host, journal


def _stores(rng):
    return tuple(rng.normal(size=(2, 2, 4, 4)).astype(np.float32)
                 for _ in range(4))


def _run_journaled_trace(seed: int):
    """Random alloc/incref/decref/demote/promote churn against the real
    allocator + host store, fully journaled and fully drained."""
    rng = np.random.default_rng(seed)
    alloc, host, journal = _journaled_pair(n_pages=int(rng.integers(4, 10)))
    live = {}                                 # device page -> refcount
    swapped = {}                              # handle -> refcount
    for _ in range(int(rng.integers(40, 120))):
        op = rng.random()
        if op < 0.35 and alloc.n_free > 0:
            (p,) = alloc.alloc(1)
            live[p] = 1
        elif op < 0.50 and live:
            p = int(rng.choice(list(live)))
            alloc.incref(p)
            live[p] += 1
        elif op < 0.75 and live:
            p = int(rng.choice(list(live)))
            alloc.decref(p)
            live[p] -= 1
            if live[p] == 0:
                del live[p]
        elif op < 0.88 and live:
            p = int(rng.choice(list(live)))
            refs = alloc.demote(p)
            assert refs == live.pop(p)
            h = host.put(_stores(rng), refs)
            swapped[h] = refs
        elif swapped and alloc.n_free > 0:
            h = rng.permutation(len(swapped))[0]
            h = list(swapped)[int(h)]
            _, refs = host.pop(h)
            assert refs == swapped.pop(h)
            live[alloc.promote(refs)] = refs
    # drain: release the device tier first (guaranteeing free pages), then
    # promote every swapped page home and release it too
    for p, refs in list(live.items()):
        for _ in range(refs):
            alloc.decref(p)
    for h in list(swapped):
        _, refs = host.pop(h)
        page = alloc.promote(refs)
        for _ in range(refs):
            alloc.decref(page)
        del swapped[h]
    assert alloc.check_balanced() and host.check_balanced()
    return journal


@pytest.mark.parametrize("seed", range(8))
def test_real_traces_replay_clean(seed):
    journal = _run_journaled_trace(seed)
    assert len(journal) > 0
    assert replay_check(journal.events) == []


def _clean_events(seed=3):
    """A clean journal guaranteed to contain a demote→promote round trip."""
    for s in range(seed, seed + 50):
        evs = _run_journaled_trace(s).events
        if any(e["ev"] == "host_pop" for e in evs):
            return copy.deepcopy(evs)
    raise AssertionError("no trace with a promote in 50 seeds")


def _kinds(violations):
    return {v.kind for v in violations}


def test_duplicated_decref_is_double_free():
    evs = _clean_events()
    # re-append the decref that freed a page (refs hit 0)
    freeing = next(e for e in evs
                   if e["ev"] == "page_decref" and e["refs"] == 0)
    evs.insert(evs.index(freeing) + 1, dict(freeing))
    v = replay_check(evs)
    assert "double-free" in _kinds(v)
    offender = next(x for x in v if x.kind == "double-free")
    assert f"page {freeing['page']}" in offender.detail


def test_dropped_decref_is_a_leak():
    evs = _clean_events()
    # drop the LAST freeing decref: its page is never re-allocated after,
    # so the only detectable symptom is the end-of-trace leak (dropping an
    # earlier one shows up as double-alloc when the id is recycled)
    freeing = [e for e in evs
               if e["ev"] == "page_decref" and e["refs"] == 0][-1]
    evs.remove(freeing)
    v = replay_check(evs)
    assert "device-leak" in _kinds(v)
    leak = next(x for x in v if x.kind == "device-leak")
    assert leak.seq == -1                     # end-of-trace check
    assert f"page {freeing['page']}" in leak.detail


def test_dropped_host_pop_breaks_tier_transfer_balance():
    evs = _clean_events()
    pop = next(e for e in evs if e["ev"] == "host_pop")
    evs.remove(pop)
    kinds = _kinds(replay_check(evs))
    # the pop's handle now leaks on the host tier AND the promote multiset
    # no longer matches the pops
    assert "host-leak" in kinds
    assert "tier-transfer-mismatch" in kinds


def test_tampered_transfer_refcount_diverges():
    evs = _clean_events()
    demote = next(e for e in evs if e["ev"] == "page_demote")
    demote["refs"] += 1                       # journal lies about the count
    kinds = _kinds(replay_check(evs))
    assert "refcount-divergence" in kinds
    assert "tier-transfer-mismatch" in kinds  # demote vs host_put refs


def test_null_page_alloc_flagged():
    v = replay_check([{"seq": 0, "ev": "page_alloc", "page": 0}])
    assert _kinds(v) == {"null-page-alloc"}


def test_use_after_free_incref_flagged():
    evs = [
        {"seq": 0, "ev": "page_alloc", "page": 3},
        {"seq": 1, "ev": "page_decref", "page": 3, "refs": 0},
        {"seq": 2, "ev": "page_incref", "page": 3, "refs": 1},
    ]
    v = replay_check(evs)
    assert _kinds(v) == {"incref-after-free"}
    assert v[0].seq == 2


def test_promote_onto_live_page_flagged():
    evs = [
        {"seq": 0, "ev": "page_alloc", "page": 2},
        {"seq": 1, "ev": "page_promote", "page": 2, "refs": 1},
    ]
    kinds = _kinds(replay_check(evs))
    assert "promote-onto-live-page" in kinds


def test_allocator_emits_nothing_when_journal_absent():
    alloc = PageAllocator(4, page_size=4)
    host = HostPageStore()
    assert alloc.journal is None and host.journal is None
    (p,) = alloc.alloc(1)
    refs = alloc.demote(p)
    h = host.put(tuple(np.zeros((1,)) for _ in range(4)), refs)
    _, back = host.pop(h)
    alloc.decref(alloc.promote(back))
    assert alloc.check_balanced() and host.check_balanced()
