"""Journal replay checker vs the real allocator: clean traces stay clean,
injected corruption is caught.

The emitting side is the production one — a ``PageAllocator`` and a
``HostPageStore`` with an ``EventJournal`` attached journal every alloc /
incref / decref / demote→put / pop→promote they actually perform.  A
randomized driver (the same op mix as ``tests/test_slot_lifecycle_fuzz``,
shrunk) produces journals that MUST replay clean; the negative tests then
tamper with those real journals — deleting, duplicating or rewriting
single events — and assert :func:`replay_check` pins each corruption:

  * duplicated ``page_decref``  -> ``double-free``
  * deleted   ``page_decref``  -> ``device-leak`` at end of trace
  * deleted   ``host_pop``     -> ``host-leak`` + ``tier-transfer-mismatch``
                                  + ``promote-onto-live-page``-free replay
  * rewritten transfer refcount -> ``refcount-divergence`` +
                                  ``tier-transfer-mismatch``
  * ``page_alloc`` of page 0    -> ``null-page-alloc``
  * use-after-free incref       -> ``incref-after-free``

The multi-replica half does the same to :func:`replay_check_multi`: a real
two-replica trace (per-replica allocator + prefix index + journal, a
``GlobalPrefixView`` feeding the router log) replays clean, then single
tampered events pin each cross-replica invariant:

  * admit copied to the other replica -> ``duplicate-admission``
  * deleted ``route``                 -> ``unrouted-admission``
  * rewritten route target            -> ``route-mismatch``
  * duplicated ``route``              -> ``duplicate-route``
  * duplicated ``view_publish``       -> ``view-double-publish``
  * deleted ``prefix_drop``           -> ``view-missing-path``
  * deleted ``view_drop``             -> ``view-stale-path``
"""
import copy

import numpy as np
import pytest

from repro.serving import (
    GlobalPrefixView, HostPageStore, PageAllocator, PrefixIndex,
)
from repro.serving.obs import EventJournal, replay_check, replay_check_multi


def _journaled_pair(n_pages=8):
    alloc = PageAllocator(n_pages, page_size=4)
    host = HostPageStore()
    journal = EventJournal()
    alloc.journal = journal
    host.journal = journal
    return alloc, host, journal


def _stores(rng):
    return tuple(rng.normal(size=(2, 2, 4, 4)).astype(np.float32)
                 for _ in range(4))


def _run_journaled_trace(seed: int):
    """Random alloc/incref/decref/demote/promote churn against the real
    allocator + host store, fully journaled and fully drained."""
    rng = np.random.default_rng(seed)
    alloc, host, journal = _journaled_pair(n_pages=int(rng.integers(4, 10)))
    live = {}                                 # device page -> refcount
    swapped = {}                              # handle -> refcount
    for _ in range(int(rng.integers(40, 120))):
        op = rng.random()
        if op < 0.35 and alloc.n_free > 0:
            (p,) = alloc.alloc(1)
            live[p] = 1
        elif op < 0.50 and live:
            p = int(rng.choice(list(live)))
            alloc.incref(p)
            live[p] += 1
        elif op < 0.75 and live:
            p = int(rng.choice(list(live)))
            alloc.decref(p)
            live[p] -= 1
            if live[p] == 0:
                del live[p]
        elif op < 0.88 and live:
            p = int(rng.choice(list(live)))
            refs = alloc.demote(p)
            assert refs == live.pop(p)
            h = host.put(_stores(rng), refs)
            swapped[h] = refs
        elif swapped and alloc.n_free > 0:
            h = rng.permutation(len(swapped))[0]
            h = list(swapped)[int(h)]
            _, refs = host.pop(h)
            assert refs == swapped.pop(h)
            live[alloc.promote(refs)] = refs
    # drain: release the device tier first (guaranteeing free pages), then
    # promote every swapped page home and release it too
    for p, refs in list(live.items()):
        for _ in range(refs):
            alloc.decref(p)
    for h in list(swapped):
        _, refs = host.pop(h)
        page = alloc.promote(refs)
        for _ in range(refs):
            alloc.decref(page)
        del swapped[h]
    assert alloc.check_balanced() and host.check_balanced()
    return journal


@pytest.mark.parametrize("seed", range(8))
def test_real_traces_replay_clean(seed):
    journal = _run_journaled_trace(seed)
    assert len(journal) > 0
    assert replay_check(journal.events) == []


def _clean_events(seed=3):
    """A clean journal guaranteed to contain a demote→promote round trip."""
    for s in range(seed, seed + 50):
        evs = _run_journaled_trace(s).events
        if any(e["ev"] == "host_pop" for e in evs):
            return copy.deepcopy(evs)
    raise AssertionError("no trace with a promote in 50 seeds")


def _kinds(violations):
    return {v.kind for v in violations}


def test_duplicated_decref_is_double_free():
    evs = _clean_events()
    # re-append the decref that freed a page (refs hit 0)
    freeing = next(e for e in evs
                   if e["ev"] == "page_decref" and e["refs"] == 0)
    evs.insert(evs.index(freeing) + 1, dict(freeing))
    v = replay_check(evs)
    assert "double-free" in _kinds(v)
    offender = next(x for x in v if x.kind == "double-free")
    assert f"page {freeing['page']}" in offender.detail


def test_dropped_decref_is_a_leak():
    evs = _clean_events()
    # drop the LAST freeing decref: its page is never re-allocated after,
    # so the only detectable symptom is the end-of-trace leak (dropping an
    # earlier one shows up as double-alloc when the id is recycled)
    freeing = [e for e in evs
               if e["ev"] == "page_decref" and e["refs"] == 0][-1]
    evs.remove(freeing)
    v = replay_check(evs)
    assert "device-leak" in _kinds(v)
    leak = next(x for x in v if x.kind == "device-leak")
    assert leak.seq == -1                     # end-of-trace check
    assert f"page {freeing['page']}" in leak.detail


def test_dropped_host_pop_breaks_tier_transfer_balance():
    evs = _clean_events()
    pop = next(e for e in evs if e["ev"] == "host_pop")
    evs.remove(pop)
    kinds = _kinds(replay_check(evs))
    # the pop's handle now leaks on the host tier AND the promote multiset
    # no longer matches the pops
    assert "host-leak" in kinds
    assert "tier-transfer-mismatch" in kinds


def test_tampered_transfer_refcount_diverges():
    evs = _clean_events()
    demote = next(e for e in evs if e["ev"] == "page_demote")
    demote["refs"] += 1                       # journal lies about the count
    kinds = _kinds(replay_check(evs))
    assert "refcount-divergence" in kinds
    assert "tier-transfer-mismatch" in kinds  # demote vs host_put refs


def test_null_page_alloc_flagged():
    v = replay_check([{"seq": 0, "ev": "page_alloc", "page": 0}])
    assert _kinds(v) == {"null-page-alloc"}


def test_use_after_free_incref_flagged():
    evs = [
        {"seq": 0, "ev": "page_alloc", "page": 3},
        {"seq": 1, "ev": "page_decref", "page": 3, "refs": 0},
        {"seq": 2, "ev": "page_incref", "page": 3, "refs": 1},
    ]
    v = replay_check(evs)
    assert _kinds(v) == {"incref-after-free"}
    assert v[0].seq == 2


def test_promote_onto_live_page_flagged():
    evs = [
        {"seq": 0, "ev": "page_alloc", "page": 2},
        {"seq": 1, "ev": "page_promote", "page": 2, "refs": 1},
    ]
    kinds = _kinds(replay_check(evs))
    assert "promote-onto-live-page" in kinds


# ---------------------------------------------------------------------------
# page_quality events (compression-quality tags)
# ---------------------------------------------------------------------------

def _quality_trace(**tag_overrides):
    tag = {"seq": 1, "ev": "page_quality", "page": 3, "count": 4,
           "rel_mean": 0.2, "rel_max": 0.4, "nnz_mean": 3.0}
    tag.update(tag_overrides)
    return [{"seq": 0, "ev": "page_alloc", "page": 3}, tag,
            {"seq": 2, "ev": "page_decref", "page": 3, "refs": 0}]


def test_clean_quality_tag_replays_clean():
    assert replay_check(_quality_trace()) == []


def test_quality_on_null_page_flagged():
    v = replay_check([{"seq": 0, "ev": "page_quality", "page": 0,
                       "count": 4, "rel_mean": 0.2, "rel_max": 0.4,
                       "nnz_mean": 3.0}])
    assert "quality-null-page" in _kinds(v)


def test_quality_on_dead_page_flagged():
    evs = [
        {"seq": 0, "ev": "page_alloc", "page": 3},
        {"seq": 1, "ev": "page_decref", "page": 3, "refs": 0},
        {"seq": 2, "ev": "page_quality", "page": 3, "count": 1,
         "rel_mean": 0.1, "rel_max": 0.1, "nnz_mean": 2.0},
    ]
    v = replay_check(evs)
    assert _kinds(v) == {"quality-on-dead-page"}
    assert v[0].seq == 2


def test_bad_quality_values_flagged():
    # each tamper breaks one statistic-sanity invariant: zero count,
    # negative residual, max below mean, non-finite fields
    for bad in ({"count": 0}, {"rel_mean": -0.5}, {"rel_max": 0.1},
                {"rel_mean": float("nan")}, {"nnz_mean": float("inf")}):
        kinds = _kinds(replay_check(_quality_trace(**bad)))
        assert "bad-quality-value" in kinds, bad


# ---------------------------------------------------------------------------
# cross-replica replay: real two-replica traces, tampered router/replica logs
# ---------------------------------------------------------------------------

def _clean_multi():
    """A real two-replica trace: per-replica allocator + prefix index +
    journal, one ``GlobalPrefixView`` feeding the router log. Three routed
    and admitted requests, every pin dropped at drain — replays clean."""
    router_log = EventJournal()
    view = GlobalPrefixView(journal=router_log)
    reps = {}
    for k in range(2):
        alloc, host, journal = _journaled_pair()
        index = PrefixIndex(page_size=2)
        index.add_observer(
            lambda p, j=journal: j.emit("prefix_publish", path=p.hex()),
            lambda p, j=journal: j.emit("prefix_drop", path=p.hex()))
        view.attach(k, index)
        reps[k] = (alloc, host, index, journal)
    for rid, k in [(0, 0), (1, 1), (2, 0)]:
        alloc, host, index, journal = reps[k]
        router_log.emit("route", rid=rid, replica=k, policy="rr", hit_pages=0)
        pages = alloc.alloc(2)
        journal.emit("admit", rid=rid, slot=0, pages=len(pages), aliased=0)
        index.register(np.arange(4) + 10 * rid, 8, pages, 4, alloc)
        alloc.free(pages)           # the slot retires; the index pin stays
    for alloc, host, index, journal in reps.values():
        index.clear(alloc, host)
        assert alloc.check_balanced()
    return ({k: copy.deepcopy(r[3].events) for k, r in reps.items()},
            copy.deepcopy(router_log.events))


def test_clean_multi_trace_replays_clean():
    replica_evs, router_evs = _clean_multi()
    assert any(e["ev"] == "prefix_publish"
               for evs in replica_evs.values() for e in evs)
    assert any(e["ev"] == "view_publish" for e in router_evs)
    assert replay_check_multi(replica_evs, router_evs) == []


def test_admit_copied_across_replicas_is_duplicate_admission():
    replica_evs, router_evs = _clean_multi()
    admit = next(e for e in replica_evs[0] if e["ev"] == "admit")
    replica_evs[1].append(dict(admit))
    kinds = _kinds(replay_check_multi(replica_evs, router_evs))
    assert "duplicate-admission" in kinds
    # the copy also landed on a replica the route never named
    assert "route-mismatch" in kinds


def test_dropped_route_is_unrouted_admission():
    replica_evs, router_evs = _clean_multi()
    route = next(e for e in router_evs if e["ev"] == "route")
    router_evs.remove(route)
    v = replay_check_multi(replica_evs, router_evs)
    assert "unrouted-admission" in _kinds(v)
    offender = next(x for x in v if x.kind == "unrouted-admission")
    assert f"rid {route['rid']}" in offender.detail


def test_rewritten_route_target_is_route_mismatch():
    replica_evs, router_evs = _clean_multi()
    route = next(e for e in router_evs if e["ev"] == "route")
    route["replica"] = 1 - route["replica"]
    kinds = _kinds(replay_check_multi(replica_evs, router_evs))
    assert "route-mismatch" in kinds


def test_duplicated_route_flagged():
    replica_evs, router_evs = _clean_multi()
    route = next(e for e in router_evs if e["ev"] == "route")
    router_evs.insert(router_evs.index(route) + 1, dict(route))
    kinds = _kinds(replay_check_multi(replica_evs, router_evs))
    assert "duplicate-route" in kinds


def test_duplicated_view_publish_flagged():
    replica_evs, router_evs = _clean_multi()
    pub = next(e for e in router_evs if e["ev"] == "view_publish")
    router_evs.insert(router_evs.index(pub) + 1, dict(pub))
    kinds = _kinds(replay_check_multi(replica_evs, router_evs))
    assert "view-double-publish" in kinds


def test_dropped_prefix_drop_is_view_missing_path():
    # the replica's journal says the chunk is still resident at end of
    # trace, but the view (which saw the real drop) no longer lists it:
    # routing could never find that cached chunk
    replica_evs, router_evs = _clean_multi()
    drop = next(e for e in replica_evs[0] if e["ev"] == "prefix_drop")
    replica_evs[0].remove(drop)
    v = replay_check_multi(replica_evs, router_evs)
    assert "view-missing-path" in _kinds(v)
    offender = next(x for x in v if x.kind == "view-missing-path")
    assert offender.seq == -1 and drop["path"] in offender.detail


def test_dropped_view_drop_is_view_stale_path():
    # the mirror image: the view still advertises a chunk whose index pin
    # is gone — a router would keep routing at a phantom prefix
    replica_evs, router_evs = _clean_multi()
    drop = next(e for e in router_evs if e["ev"] == "view_drop")
    router_evs.remove(drop)
    v = replay_check_multi(replica_evs, router_evs)
    assert "view-stale-path" in _kinds(v)
    offender = next(x for x in v if x.kind == "view-stale-path")
    assert offender.seq == -1 and drop["path"] in offender.detail


def test_allocator_emits_nothing_when_journal_absent():
    alloc = PageAllocator(4, page_size=4)
    host = HostPageStore()
    assert alloc.journal is None and host.journal is None
    (p,) = alloc.alloc(1)
    refs = alloc.demote(p)
    h = host.put(tuple(np.zeros((1,)) for _ in range(4)), refs)
    _, back = host.pop(h)
    alloc.decref(alloc.promote(back))
    assert alloc.check_balanced() and host.check_balanced()
